"""Core LSH correctness: signature generation + joins vs naive oracles.

Property-based (hypothesis) variants live in test_properties.py behind
``pytest.importorskip`` so this module always collects.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.alphabet import AMINO_ACIDS, ALPHABET_SIZE, BLOSUM62, encode_batch
from repro.core import simhash
from repro.core.hamming import all_pairs_hamming, hamming_distance, threshold_pairs
from repro.core.join import band_join, flip_join, flip_masks, pairs_to_set
from repro.core.shingle import extract_shingles, shingle_ids


def test_matmul_equals_table_k3():
    rng = np.random.default_rng(0)
    seqs = ["".join(rng.choice(list(AMINO_ACIDS), rng.integers(5, 40)))
            for _ in range(16)]
    ids, lens = encode_batch(seqs)
    for T in (11, 13, 22):
        a = np.asarray(simhash.signatures_matmul(ids, lens, k=3, T=T, f=32))
        b = np.asarray(simhash.signatures_table(ids, lens, k=3, T=T, f=32))
        np.testing.assert_array_equal(a, b)


def test_splitmix_wide_signatures():
    rng = np.random.default_rng(1)
    seqs = ["".join(rng.choice(list(AMINO_ACIDS), 30)) for _ in range(4)]
    ids, lens = encode_batch(seqs)
    s = np.asarray(simhash.signatures_table(ids, lens, k=3, T=13, f=64,
                                            scheme="splitmix"))
    assert s.shape == (4, 2) and s.dtype == np.uint32


# ------------------------------------------------------------ shingles
def test_shingle_extraction_and_mask():
    ids, lens = encode_batch(["ARNDC", "AR"])
    sh, mask = extract_shingles(ids, lens, 3)
    assert sh.shape == (2, 3, 3)
    np.testing.assert_array_equal(np.asarray(mask), [[1, 1, 1], [0, 0, 0]])
    wid = np.asarray(shingle_ids(sh))
    # 'ARN' = 0*400 + 1*20 + 2 = 22
    assert wid[0, 0] == 22
    assert (wid[1] == -1).all()


# ------------------------------------------------------------ hamming
def test_hamming_distance_matches_popcount_examples():
    rng = np.random.default_rng(8)
    for a, b in rng.integers(0, 2**32, (32, 2), dtype=np.uint32):
        d = int(hamming_distance(jnp.uint32([a]), jnp.uint32([b])))
        assert d == bin(int(a) ^ int(b)).count("1")


def test_all_pairs_hamming_blocked_vs_direct():
    rng = np.random.default_rng(2)
    q = rng.integers(0, 2**32, (7, 2), dtype=np.uint32)
    r = rng.integers(0, 2**32, (13, 2), dtype=np.uint32)
    got = np.asarray(all_pairs_hamming(jnp.asarray(q), jnp.asarray(r), block=4))
    want = np.zeros((7, 13), np.int32)
    for i in range(7):
        for j in range(13):
            want[i, j] = sum(bin(int(q[i, w]) ^ int(r[j, w])).count("1")
                             for w in range(2))
    np.testing.assert_array_equal(got, want)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(3)
    bits = rng.integers(0, 2, (5, 64))
    packed = simhash.pack_bits(jnp.asarray(bits))
    back = np.asarray(simhash.unpack_bits(packed, 64))
    np.testing.assert_array_equal(back, bits)


# ------------------------------------------------------------ joins
def _brute_pairs(q, r, d):
    out = set()
    for i in range(q.shape[0]):
        for j in range(r.shape[0]):
            dist = sum(bin(int(q[i, w]) ^ int(r[j, w])).count("1")
                       for w in range(q.shape[1]))
            if dist <= d:
                out.add((i, j))
    return out


@pytest.mark.parametrize("d", [0, 1, 2])
def test_flip_join_exact(d):
    rng = np.random.default_rng(4)
    base = rng.integers(0, 2**32, (20, 1), dtype=np.uint32)
    # plant near-duplicates at controlled distances
    q = base.copy()
    q[3, 0] ^= 1        # distance 1 from ref 3
    q[7, 0] ^= 0b101    # distance 2 from ref 7
    got, count = flip_join(jnp.asarray(q), jnp.asarray(base), f=32, d=d,
                           max_pairs=512)
    want = _brute_pairs(q, base, d)
    assert pairs_to_set(got) == want
    assert int(count) == len(want)


@pytest.mark.parametrize("f,d,bands", [(32, 0, 1), (32, 1, 2), (32, 2, 3),
                                       (64, 2, 3), (64, 3, 4)])
def test_band_join_exact(f, d, bands):
    rng = np.random.default_rng(5)
    nw = f // 32
    r = rng.integers(0, 2**32, (24, nw), dtype=np.uint32)
    q = r.copy()
    for i in range(q.shape[0]):  # mutate i%4 bits of query i
        for b in range(i % 4):
            q[i, b % nw] ^= np.uint32(1) << np.uint32((7 * i + b) % 32)
    got, count, truncated = band_join(jnp.asarray(q), jnp.asarray(r), f=f,
                                      d=d, max_pairs=2048, bands=bands)
    want = _brute_pairs(q, r, d)
    assert pairs_to_set(got) == want
    assert int(count) == len(want)
    assert not bool(truncated)


def test_threshold_pairs_dense():
    rng = np.random.default_rng(6)
    r = rng.integers(0, 2**32, (10, 1), dtype=np.uint32)
    q = r.copy(); q[2, 0] ^= 3
    got, count = threshold_pairs(jnp.asarray(q), jnp.asarray(r), 2, 256)
    want = _brute_pairs(q, r, 2)
    assert pairs_to_set(got) == want and int(count) == len(want)


def test_flip_masks_counts():
    m = flip_masks(32, 2)
    assert m.shape[0] == 1 + 32 + 32 * 31 // 2  # 529, as in the paper


# ------------------------------------------------------------ LSH property
def test_random_hyperplane_cosine_property():
    """Pr[bit agree] ≈ 1 - θ/π (paper §3) for splitmix hyperplanes."""
    rng = np.random.default_rng(7)
    f = 512  # many hyperplanes to tighten the estimate
    W = 4096
    H = (rng.integers(0, 2, (W, f)) * 2 - 1).astype(np.int32)
    for _ in range(3):
        x = rng.normal(size=W); y = rng.normal(size=W)
        # correlate y with x by random mixing
        alpha = rng.uniform(0, 1)
        y = alpha * x + (1 - alpha) * y
        vx, vy = x @ H, y @ H
        agree = np.mean((vx >= 0) == (vy >= 0))
        theta = np.arccos(np.dot(x, y) / (np.linalg.norm(x) * np.linalg.norm(y)))
        assert abs(agree - (1 - theta / np.pi)) < 0.06
