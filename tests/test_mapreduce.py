"""Distributed MapReduce engine: shuffle/reduce/salting/ring-sweep.

Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (the main test process
keeps the default 1-device view, per the brief).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.mapreduce import reduce_join, salt_hot_keys


def test_reduce_join_cross_product():
    # bucket 7: queries {10, 11}, refs {20, 21, 22} -> 6 pairs
    # bucket 9: query {12}, ref {23}               -> 1 pair
    # bucket 5: refs only                          -> 0 pairs
    keys = jnp.uint32([7, 7, 7, 7, 7, 9, 9, 5, 0xFFFFFFFF])
    ids = jnp.int32([10, 20, 11, 21, 22, 12, 23, 24, -1])
    isq = jnp.int32([1, 0, 1, 0, 0, 1, 0, 0, 0])
    pairs, total = reduce_join(keys, jnp.stack([ids, isq], -1), max_pairs=32)
    got = {(int(a), int(b)) for a, b in np.asarray(pairs) if a >= 0}
    want = {(10, 20), (10, 21), (10, 22), (11, 20), (11, 21), (11, 22),
            (12, 23)}
    assert got == want and int(total) == 7


def test_reduce_join_overflow_reports_true_total():
    keys = jnp.uint32([3] * 8)
    ids = jnp.int32([0, 1, 2, 3, 100, 101, 102, 103])
    isq = jnp.int32([1, 1, 1, 1, 0, 0, 0, 0])  # 4 queries x 4 refs = 16
    pairs, total = reduce_join(keys, jnp.stack([ids, isq], -1), max_pairs=5)
    assert int(total) == 16  # true count, even though only 5 emitted
    assert (np.asarray(pairs)[:, 0] >= 0).sum() == 5


def test_salting_rekeys_only_hot_refs():
    keys = jnp.uint32([42] * 10 + [7, 8])
    isq = jnp.asarray([True, True] + [False] * 10)
    new, hot = salt_hot_keys(keys, hot_threshold=4, n_salt=4, is_query=isq,
                             replicate_queries=False)
    new = np.asarray(new)
    assert bool(hot[0]) and not bool(hot[-1])
    assert new[0] == 42 and new[1] == 42          # queries keep their key
    assert (new[2:10] != 42).all()                # hot refs re-keyed
    assert new[10] == 7 and new[11] == 8          # cold keys untouched
    assert len(set(new[2:10].tolist())) <= 4      # at most n_salt sub-buckets


_DISTRIBUTED_CHECK = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import encode_batch
    from repro.core.alphabet import AMINO_ACIDS
    from repro.core.simhash import signatures_table
    from repro.core.mapreduce import (distributed_flip_join, MapReduceConfig,
                                      ring_sweep)
    from repro.core.join import flip_join, pairs_to_set

    assert jax.device_count() == 4
    mesh = jax.make_mesh((4,), ('data',))
    rng = np.random.default_rng(0)
    refs = [''.join(rng.choice(list(AMINO_ACIDS), 60)) for _ in range(32)]
    qrys = [r[:55] for r in refs[:8]] + \\
           [''.join(rng.choice(list(AMINO_ACIDS), 60)) for _ in range(24)]
    rids_, rlen = encode_batch(refs, 64)
    qids_, qlen = encode_batch(qrys, 64)
    rs = signatures_table(rids_, rlen, k=3, T=13, f=32)
    qs = signatures_table(qids_, qlen, k=3, T=13, f=32)
    pt, _ = flip_join(qs, rs, f=32, d=1, max_pairs=4096)
    truth = pairs_to_set(pt)
    qid = jnp.arange(32, dtype=jnp.int32); rid = jnp.arange(32, dtype=jnp.int32)
    for salting in (False, True):
        cfg = MapReduceConfig(n_shards=4, shuffle_capacity=2048,
                              max_pairs_per_shard=4096, salting=salting)
        pairs, counts, dropped = distributed_flip_join(
            qs, rs, qid, rid, f=32, d=1, mesh=mesh, cfg=cfg)
        got = pairs_to_set(np.asarray(pairs).reshape(-1, 2))
        assert np.asarray(dropped).sum() == 0
        assert got == truth, (salting, got ^ truth)
    rp, rc = ring_sweep(qs, rs, d=1, mesh=mesh, max_pairs_per_shard=4096)
    assert pairs_to_set(np.asarray(rp).reshape(-1, 2)) == truth
    # Skew stress: 16 identical ref signatures (one hot bucket) + salting.
    rs_hot = jnp.tile(rs[:1], (16, 1))
    qs_hot = jnp.tile(qs[:1], (4, 1))
    pt2, _ = flip_join(qs_hot, rs_hot, f=32, d=0, max_pairs=4096)
    truth2 = pairs_to_set(pt2)
    cfg = MapReduceConfig(n_shards=4, shuffle_capacity=2048,
                          max_pairs_per_shard=4096, salting=True,
                          hot_threshold=2, n_salt=4)
    pairs, _, dropped = distributed_flip_join(
        qs_hot, rs_hot, jnp.arange(4, dtype=jnp.int32),
        jnp.arange(16, dtype=jnp.int32), f=32, d=0, mesh=mesh, cfg=cfg)
    got2 = pairs_to_set(np.asarray(pairs).reshape(-1, 2))
    assert np.asarray(dropped).sum() == 0
    assert got2 == truth2, got2 ^ truth2
    print('DISTRIBUTED_OK')
""")


@pytest.mark.slow
def test_distributed_join_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _DISTRIBUTED_CHECK],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DISTRIBUTED_OK" in out.stdout
