"""Launch-layer units that don't need the 512-device mesh: cell matrix
rules, model-flops accounting, report rendering."""
import json

import pytest

from repro.configs import ARCHS, SHAPES, cells, shape_applicable, get_config
from repro.launch.report import fmt_table, FIX_NOTES
from repro.launch.roofline import (Roofline, model_flops_for, PEAK_FLOPS,
                                   HBM_BW, ICI_BW)
from repro.models.config import active_param_count


def test_cell_matrix_counts():
    all_cells = cells()
    assert len(all_cells) == 40  # 10 archs x 4 shapes
    runnable = [c for c in all_cells if c[2]]
    skipped = [c for c in all_cells if not c[2]]
    assert len(runnable) == 31
    assert len(skipped) == 9
    # hubert: 2 skips; 8 archs skip long_500k (incl. hubert counted once)
    hub = [c for c in skipped if c[0] == "hubert-xlarge"]
    assert len(hub) == 2
    longs = [c for c in skipped if c[1] == "long_500k"]
    assert len(longs) == 8
    for _, _, ok, why in skipped:
        assert why  # every skip carries a reason


def test_subquadratic_archs_run_long_500k():
    assert shape_applicable("recurrentgemma-2b", "long_500k")[0]
    assert shape_applicable("xlstm-1.3b", "long_500k")[0]
    assert not shape_applicable("yi-9b", "long_500k")[0]


def test_model_flops_accounting():
    cfg = get_config("yi-9b")
    n = active_param_count(cfg)
    t = model_flops_for(cfg, "train_4k", n, 4096, 256, "train")
    p = model_flops_for(cfg, "prefill_32k", n, 32768, 32, "prefill")
    d = model_flops_for(cfg, "decode_32k", n, 32768, 128, "decode")
    assert t == 6.0 * n * 4096 * 256
    assert p == 2.0 * n * 32768 * 32
    assert d == 2.0 * n * 128          # one token per sequence


def test_moe_active_flops_smaller_than_total():
    cfg = get_config("qwen3-moe-30b-a3b")
    from repro.models.config import param_count
    assert active_param_count(cfg) < 0.2 * param_count(cfg)


def test_hardware_constants_match_brief():
    assert PEAK_FLOPS == 197e12 and HBM_BW == 819e9 and ICI_BW == 50e9


def test_report_renders_skips_and_cells(tmp_path):
    r = Roofline(arch=ARCHS[0], shape="train_4k", mesh="single", chips=256,
                 hlo_flops=1e12, hlo_bytes=1e12, collective_bytes=1e10,
                 collectives={}, model_flops=1e15,
                 peak_memory_bytes=2**30).finalize()
    cells_map = {(ARCHS[0], "train_4k", "single"): json.loads(
        json.dumps(r.__dict__))}
    table = fmt_table(cells_map, "single")
    assert "SKIP" in table               # skipped cells rendered with reason
    assert ARCHS[0] in table
    assert "(missing)" in table          # un-run cells flagged, not hidden
    for note in FIX_NOTES.values():
        assert isinstance(note, str) and note


def test_roofline_bottleneck_note_exists_for_every_term():
    assert set(FIX_NOTES) == {"compute", "memory", "collective"}
