"""Sequence-parallel KV-cache decode == unsharded decode (exactness).

The decode_32k cells depend on seq_sharded_decode_attention (cache seq axis
on "model" with a pmax/psum flash combine). This test runs the same decode
on a (2, 2) ("data","model") mesh with the sharded cache and on a plain
1-device path, and demands matching logits.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_CHECK = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from functools import partial
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import (ModelConfig, init_params, init_cache,
                              decode_step, prefill)
    from repro.models.sharding import make_rules, cache_spec_tree

    assert jax.device_count() == 4
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      attn_chunk=8, ce_chunk=8, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 4, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    MAXLEN = 16  # divisible by model axis (2) -> seq-shard path triggers

    # ---- reference: plain decode, no mesh
    cache0 = init_cache(cfg, B, MAXLEN)
    lg_ref, c_ref = prefill(params, toks[:, :8], cache0, cfg)
    outs_ref = [lg_ref]
    cr = c_ref
    for t in range(8, S):
        lg, cr = decode_step(params, cr, toks[:, t:t+1], jnp.int32(t), cfg)
        outs_ref.append(lg)

    # ---- sharded: cache seq axis on "model"
    rules = make_rules(cfg, mesh)
    assert rules["kv_seq"] == "model"
    with mesh:
        cache = init_cache(cfg, B, MAXLEN)
        cspecs = cache_spec_tree(cache, cfg, rules)
        cache = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            cache, cspecs, is_leaf=lambda x: hasattr(x, "shape"))
        # prefill runs the chunked (concat) path; decode the seq-shard path
        lg, cache = prefill(params, toks[:, :8], cache, cfg, rules)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(outs_ref[0]),
                                   rtol=2e-4, atol=2e-4)
        dstep = jax.jit(partial(decode_step, cfg=cfg, rules=rules))
        for i, t in enumerate(range(8, S)):
            lg, cache = dstep(params, cache, toks[:, t:t+1], jnp.int32(t))
            np.testing.assert_allclose(np.asarray(lg),
                                       np.asarray(outs_ref[i + 1]),
                                       rtol=2e-4, atol=2e-4)
    print("SEQ_SHARD_DECODE_OK")
""")


@pytest.mark.slow
def test_seq_sharded_decode_matches_unsharded():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _CHECK],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SEQ_SHARD_DECODE_OK" in out.stdout
