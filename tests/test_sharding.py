"""Bucket-partition layer + sharded consumers: ownership/slab invariants,
probe top-k and self-join pair-set equality for n_shards in {1, 2, 4}
(in-process via the vmap path, and under 4 forced host devices in a
subprocess for the real shard_map/ppermute programs), add() re-placement,
and save->load round-trip of a sharded index."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.allpairs import lsh_self_join
from repro.core import LSHConfig, ScalLoPS
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import (BucketPartition, ShardedIndex, SignatureIndex,
                         bucket_owners, config_fingerprint)
from repro.index.service import topk_probe

CFG = LSHConfig(k=3, T=13, f=32, d=1)


@pytest.fixture(scope="module")
def data():
    return make_protein_sets(SyntheticProteinConfig(
        n_refs=120, n_homolog_queries=20, n_decoy_queries=20,
        ref_len_mean=90, ref_len_std=12, sub_rates=(0.04, 0.1), seed=31))


@pytest.fixture(scope="module")
def index(data):
    return SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])


@pytest.fixture(scope="module")
def q_sigs(data):
    return ScalLoPS(CFG).signatures(data["query_ids"], data["query_lens"])


# ---------------------------------------------------------------- partition
@pytest.mark.parametrize("n", [1, 2, 4, 7])
def test_partition_buckets_are_whole_and_exhaustive(index, n):
    """Every bucket lands on exactly the shard mix32(key) % n owns, intact:
    the union of shard sub-CSRs is the original bucket table."""
    index._ensure_built()
    part = index.partition(n)
    assert part.n_shards == n
    for b, (keys, offsets, ids) in enumerate(index._csr_np):
        own = bucket_owners(keys, n)
        seen_keys, seen_members = [], {}
        for s in range(n):
            skeys, soffs, sids = part.shards[s][b]
            np.testing.assert_array_equal(own[np.isin(keys, skeys)], s)
            for u, key in enumerate(skeys):
                seen_keys.append(int(key))
                seen_members[int(key)] = sids[soffs[u]:soffs[u + 1]]
        assert sorted(seen_keys) == sorted(int(k) for k in keys)
        for u, key in enumerate(keys):
            np.testing.assert_array_equal(
                seen_members[int(key)], ids[offsets[u]:offsets[u + 1]])
    # pair totals sum to the unsharded total
    sizes = [np.diff(o).astype(np.int64) for _, o, _ in index._csr_np]
    want = sum(int((s * (s - 1) // 2).sum()) for s in sizes)
    assert int(part.pair_totals.sum()) == want


def test_partition_single_shard_slab_matches_probe_layout(index):
    """The 1-way partition IS the single-device probe layout (one stacking
    code path): shard 0's slab holds every band's full CSR."""
    index._ensure_built()
    part = index.partition(1)
    keys_s, offs_s, ids_s = (np.asarray(a) for a in part.device_slabs())
    assert keys_s.shape[0] == 1
    for b, (keys, offsets, ids) in enumerate(index._csr_np):
        u, e = len(keys), len(ids)
        np.testing.assert_array_equal(keys_s[0, b, :u], keys)
        np.testing.assert_array_equal(offs_s[0, b, :u + 1], offsets)
        np.testing.assert_array_equal(ids_s[0, b, :e], ids)


def test_partition_cache_invalidated_by_add(data, index):
    half = SignatureIndex.build(CFG, data["ref_ids"][:60],
                                data["ref_lens"][:60])
    p_before = half.partition(2)
    half.add(data["ref_ids"][60:], data["ref_lens"][60:])
    p_after = half.partition(2)
    assert p_after is not p_before
    assert int(p_after.n_entries.sum()) > int(p_before.n_entries.sum())


# ----------------------------------------------------------- vmap fallbacks
@pytest.mark.parametrize("n", [2, 4])
def test_selfjoin_sharded_pair_set_identical_inprocess(index, n):
    """n-way sharded emission (vmap path on one device) produces the
    bit-identical pair arrays, with and without the Hamming filter."""
    base = lsh_self_join(index)
    got = lsh_self_join(index, n_shards=n)
    np.testing.assert_array_equal(base.pairs, got.pairs)
    np.testing.assert_array_equal(base.indptr, got.indptr)
    base_d = lsh_self_join(index, d=CFG.d)
    got_d = lsh_self_join(index, d=CFG.d, n_shards=n)
    np.testing.assert_array_equal(base_d.pairs, got_d.pairs)


def test_selfjoin_uses_index_default_shards(data):
    """An index built with n_shards=2 self-joins through the 2-way
    partition by default — same pairs as the explicit override."""
    idx2 = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"],
                                n_shards=2)
    idx1 = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    np.testing.assert_array_equal(lsh_self_join(idx2).pairs,
                                  lsh_self_join(idx1).pairs)


# -------------------------------------------------------- skew-bounded caps
def test_selfjoin_skew_bounded_caps(data):
    """One degenerate bucket no longer inflates every shard's emission
    buffer: per-shard caps follow per-shard demand (ragged host merge),
    and the pair arrays are unchanged."""
    from repro.allpairs.selfjoin import _shard_caps
    # 40 copies of one sequence -> one degenerate bucket on one shard
    ids = np.concatenate([data["ref_ids"][:1].repeat(40, axis=0),
                          data["ref_ids"]], axis=0)
    lens = np.concatenate([data["ref_lens"][:1].repeat(40),
                           data["ref_lens"]])
    idx = SignatureIndex.build(CFG, ids, lens)
    base = lsh_self_join(idx)
    for n in (2, 4):
        caps = _shard_caps(idx.partition(n))
        # skewed demand: the degenerate shard's cap dominates, the others
        # stay at their own (much smaller) demand
        assert len(set(caps.tolist())) > 1, caps
        assert int(caps.sum()) < n * int(caps.max())
        got = lsh_self_join(idx, n_shards=n)
        np.testing.assert_array_equal(base.pairs, got.pairs)
        np.testing.assert_array_equal(base.indptr, got.indptr)
    # a non-pow2 max_grow between the true demand and its quantized buffer
    # size must not raise: overflow is judged on TRUE demand, quantization
    # only sizes buffers
    need = int(idx.partition(1).pair_totals.max())
    from repro.util import next_pow2
    assert next_pow2(need) > need + 1       # the quantized cap exceeds it
    lsh_self_join(idx, max_grow=need + 1)


def test_shard_caps_quantized_pow2(data):
    from repro.allpairs.selfjoin import _shard_caps
    from repro.util import next_pow2
    assert [next_pow2(x) for x in (0, 1, 2, 3, 65)] == [0, 1, 2, 4, 128]
    caps = _shard_caps(SignatureIndex.build(
        CFG, data["ref_ids"], data["ref_lens"]).partition(4))
    assert all(c == 0 or c == next_pow2(c) for c in caps.tolist())


# ------------------------------------------------------- jit-cache keying
def test_emit_program_cache_survives_fresh_mesh(data):
    """Regression (ROADMAP PR 4 trap): the sharded emission program is
    cached by DEVICE TUPLE, so constructing a new-but-equal Mesh per call
    resolves to the identical jitted program — no silent recompile."""
    import jax
    from jax.sharding import Mesh
    from repro.allpairs.selfjoin import (_emit_sharded_cached,
                                         _emit_sharded_fn)
    m1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    m2 = Mesh(np.array(jax.devices()[:1]), ("data",))
    size0 = _emit_sharded_cached.cache_info().currsize
    f1 = _emit_sharded_fn(m1, "data", 16)
    f2 = _emit_sharded_fn(m2, "data", 16)
    assert f1 is f2
    assert _emit_sharded_cached.cache_info().currsize == size0 + 1


def test_wave_fns_cache_keyed_by_device_tuple():
    """Regression (same PR 4 trap, wave side): the SPMD wave programs in
    allpairs.tiles are cached by DEVICE TUPLE — not a bare device count —
    so repeated calls with the same devices share one compiled program,
    and a different device subset cannot alias a stale entry."""
    import jax
    from repro.allpairs.tiles import _sharded_wave_fns
    devs = tuple(jax.devices()[:1])
    size0 = _sharded_wave_fns.cache_info().currsize
    f1 = _sharded_wave_fns(devs)
    f2 = _sharded_wave_fns(tuple(jax.devices()[:1]))    # fresh tuple, same devs
    assert f1 is f2
    assert _sharded_wave_fns.cache_info().currsize == size0 + 1
    # the key is the devices themselves: hashable, and a list (unhashable,
    # the bug a bare-count key invites back) is rejected loudly
    with pytest.raises(TypeError):
        _sharded_wave_fns(list(jax.devices()[:1]))


# ---------------------------------------------------------------- persistence
def test_sharded_index_roundtrip_and_fingerprint(tmp_path, data, q_sigs):
    idx = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"],
                               n_shards=4)
    # n_shards is part of the fingerprint (and omitted when 1 — the
    # pre-sharding fingerprint stays valid)
    assert idx.fingerprint != config_fingerprint(
        CFG, layout=idx.layout, bands=idx.bands, key_hash=idx.key_hash)
    path = tmp_path / "sharded.npz"
    idx.save(path)
    loaded = SignatureIndex.load(path, expected_cfg=CFG)
    assert loaded.n_shards == 4 and loaded.fingerprint == idx.fingerprint
    a = topk_probe(idx, q_sigs, k=5, cap=256)
    b = topk_probe(loaded, q_sigs, k=5, cap=256)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(lsh_self_join(idx).pairs,
                                  lsh_self_join(loaded).pairs)


# ------------------------------------------------------- forced 4 devices
_SUBPROCESS = """
import numpy as np
import jax
assert jax.device_count() == 4, jax.devices()
from jax.sharding import Mesh

from repro.allpairs import WaveConfig, lsh_self_join, score_pairs
from repro.core import LSHConfig, ScalLoPS
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import ShardedIndex, SignatureIndex
from repro.index.service import topk_probe

data = make_protein_sets(SyntheticProteinConfig(
    n_refs=150, n_homolog_queries=16, n_decoy_queries=16,
    ref_len_mean=90, ref_len_std=12, sub_rates=(0.04, 0.1), seed=41))
cfg = LSHConfig(k=3, T=13, f=32, d=1)
idx = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"])
sl = ScalLoPS(cfg)
q = sl.signatures(data["query_ids"], data["query_lens"])

# --- probe top-k identical for n_shards in {1, 2, 4} (bit-exact, real
# shard_map ring on distinct mesh sizes)
want_id, want_d, want_cap, want_tr = topk_probe(idx, q, k=6, cap=32)
want_id, want_d = np.asarray(want_id), np.asarray(want_d)
for n in (1, 2, 4):
    sh = ShardedIndex(idx, Mesh(np.array(jax.devices()[:n]), ("data",)))
    nid, nd, cap, tr = sh.topk(q, k=6, cap=32)
    np.testing.assert_array_equal(nid, want_id)
    np.testing.assert_array_equal(nd, want_d)
    assert (cap, tr) == (want_cap, want_tr), (n, cap, tr)
    # ragged batch (B % n != 0): padded query rows must not perturb
    # results OR the overflow contract
    r_id, r_d, r_cap, r_tr = sh.topk(q[:29], k=6, cap=32)
    w_id, w_d, w_cap, w_tr = topk_probe(idx, q[:29], k=6, cap=32)
    np.testing.assert_array_equal(r_id, np.asarray(w_id))
    np.testing.assert_array_equal(r_d, np.asarray(w_d))
    assert (r_cap, r_tr) == (w_cap, w_tr), (n, r_cap, r_tr)
print("PROBE-EXACT")

# --- self-join pair set identical for n_shards in {1, 2, 4} (shard_map)
base = lsh_self_join(idx, d=cfg.d)
for n in (2, 4):
    got = lsh_self_join(idx, d=cfg.d, n_shards=n)
    np.testing.assert_array_equal(base.pairs, got.pairs)
print("SELFJOIN-EXACT")

# --- add(): grow the index; the replica ingests the DELTA slab (no full
# re-place) and still matches the single-device probe over the grown corpus
extra = make_protein_sets(SyntheticProteinConfig(
    n_refs=40, n_homolog_queries=1, n_decoy_queries=1,
    ref_len_mean=90, ref_len_std=12, sub_rates=(0.05,), seed=43))
sh4 = ShardedIndex(idx)            # snapshots the 150-ref partition
nid0, *_ = sh4.topk(q, k=6, cap=64)
idx.add(extra["ref_ids"], extra["ref_lens"])
nid, nd, *_ = sh4.topk(q, k=6, cap=64)      # delta refresh, not a reload
assert sh4._delta is not None, "expected base+delta slabs after add()"
want_id2, want_d2, *_ = topk_probe(idx, q, k=6, cap=64)
np.testing.assert_array_equal(nid, np.asarray(want_id2))
np.testing.assert_array_equal(nd, np.asarray(want_d2))
got = lsh_self_join(idx, n_shards=4)
np.testing.assert_array_equal(lsh_self_join(idx, n_shards=1).pairs,
                              got.pairs)
print("ADD-EXACT")

# --- flip layout under sharding: the expanded table partitions the same
# way (n_bands == 1); ring probe bit-exact for every n_shards
idxf = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"],
                            layout="flip")
wf = topk_probe(idxf, q, k=6, cap=64)
for n in (1, 2, 4):
    shf = ShardedIndex(idxf, Mesh(np.array(jax.devices()[:n]), ("data",)))
    gf = shf.topk(q, k=6, cap=64)
    np.testing.assert_array_equal(gf[0], np.asarray(wf[0]))
    np.testing.assert_array_equal(gf[1], np.asarray(wf[1]))
    assert (gf[2], gf[3]) == (wf[2], wf[3])
print("FLIP-EXACT")

# --- fresh-Mesh recompile trap: two self-joins through two freshly
# constructed (equal) meshes must reuse ONE cached emission program.
# A uniform-demand corpus (identical rows -> one live bucket, one live
# cap) pins the SPMD shard_map path; skewed corpora take the ragged
# per-shard path, which never builds a mesh program at all.
from repro.allpairs.selfjoin import _emit_sharded_cached, _emit_sharded_fn
uni = SignatureIndex.build(cfg, np.repeat(data["ref_ids"][:1], 24, axis=0),
                           np.repeat(data["ref_lens"][:1], 24))
_emit_sharded_cached.cache_clear()
m1 = Mesh(np.array(jax.devices()[:4]), ("data",))
j1 = lsh_self_join(uni, n_shards=4, mesh=m1)
info = _emit_sharded_cached.cache_info()
assert info.currsize == 1, info         # the SPMD path actually ran
m2 = Mesh(np.array(jax.devices()[:4]), ("data",))
j2 = lsh_self_join(uni, n_shards=4, mesh=m2)
info = _emit_sharded_cached.cache_info()
assert info.currsize == 1 and info.hits >= 1, info
np.testing.assert_array_equal(j1.pairs, j2.pairs)
assert _emit_sharded_fn(m1, "data", 32) is _emit_sharded_fn(
    Mesh(np.array(jax.devices()[:4]), ("data",)), "data", 32)
print("CACHE-STABLE")

# --- save -> load round-trip of a sharded index, served sharded
import tempfile, os
path = os.path.join(tempfile.mkdtemp(), "sharded.npz")
idx4 = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"],
                            n_shards=4)
idx4.save(path)
loaded = SignatureIndex.load(path, expected_cfg=cfg)
assert loaded.n_shards == 4
shl = ShardedIndex(loaded)
nid, nd, *_ = shl.topk(q, k=6, cap=32)
np.testing.assert_array_equal(nid, want_id)
np.testing.assert_array_equal(nd, want_d)
print("ROUNDTRIP-EXACT")

# --- multi-device waves bit-exact vs single device
rng = np.random.default_rng(2)
ids, lens = data["ref_ids"], data["ref_lens"]
pairs = np.stack([rng.integers(0, 150, 48), rng.integers(0, 150, 48)],
                 axis=1).astype(np.int32)
s1 = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=8))
s4 = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=8, n_devices=4))
np.testing.assert_array_equal(s1.scores, s4.scores)
p1 = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=8, prefilter=True))
p4 = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=8, prefilter=True,
                                              n_devices=4))
np.testing.assert_array_equal(p1.scores, p4.scores)
np.testing.assert_array_equal(p1.kept, p4.kept)
print("WAVES-EXACT")
"""


@pytest.mark.slow
def test_sharded_paths_forced_four_devices():
    """The real multi-device programs (shard_map emission, ppermute probe
    ring, SPMD-split waves) under XLA_FLAGS-forced 4 host devices."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS], env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    for marker in ("PROBE-EXACT", "SELFJOIN-EXACT", "ADD-EXACT",
                   "FLIP-EXACT", "CACHE-STABLE",
                   "ROUNDTRIP-EXACT", "WAVES-EXACT"):
        assert marker in out.stdout, (marker, out.stdout, out.stderr)
