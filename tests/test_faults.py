"""repro.faults: deterministic fault injection, supervised workers, and
the fault paths they exercise in the serving tier.

The invariants pinned here:

* **the plan is a script, not a dice roll** — per-site call counting is
  1-based and exact, every fired fault lands in the ledger, and a plan
  replayed over the same call sequence fires identically;
* **atomic_write is all-or-nothing** — a crash mid-write (including the
  plan's scripted ``torn`` kind, the deliberately non-atomic writer)
  never leaves a half-new destination behind the happy path;
* **supervised workers never die silently** — a crash restarts the loop
  with deterministic seeded backoff, bounded consecutive failures latch
  a visible ``degraded``, and every outstanding future/ticket resolves
  typed (``Rejected("internal")`` / ``IngestTicket.error``) first;
* **the router degrades, never throws** — replica failures are retried
  once on a healthy replica, repeat offenders are quarantined with
  half-open probe readmission, and a fully-down fleet answers with a
  typed coverage-carrying :class:`DegradedBatch`.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import LSHConfig
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.faults import (FaultPlan, FaultSpec, InjectedFault, Supervisor,
                          ThreadKilled, atomic_write, fault_point)
from repro.index import QueryEngine, ServingConfig, SignatureIndex
from repro.serve import (AsyncEngine, Completed, Degraded, DegradedBatch,
                         Rejected, ReplicaFleet)

CFG = LSHConfig(k=3, T=13, f=32, d=1)
SCFG = ServingConfig(k=5, max_batch=8, mode="probe")


@pytest.fixture(scope="module")
def data():
    return make_protein_sets(SyntheticProteinConfig(
        n_refs=120, n_homolog_queries=8, n_decoy_queries=8,
        ref_len_mean=90, ref_len_std=12, sub_rates=(0.04, 0.1), seed=77))


@pytest.fixture(scope="module")
def index(data):
    idx = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    idx._ensure_built()
    return idx


class FakeClock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ------------------------------------------------------------ the plan
def test_plan_counts_calls_and_fires_exactly():
    plan = FaultPlan().add("a.site", "raise", on={2, 4})
    with plan:
        assert fault_point("a.site") is None            # call 1
        with pytest.raises(InjectedFault) as ei:
            fault_point("a.site")                       # call 2 fires
        assert ei.value.site == "a.site" and ei.value.call == 2
        assert fault_point("a.site") is None            # call 3
        with pytest.raises(InjectedFault):
            fault_point("a.site")                       # call 4 fires
        assert fault_point("other.site") is None        # independent counter
    assert plan.calls("a.site") == 4
    assert plan.calls("other.site") == 1
    assert plan.fired("a.site") == 2 and plan.fired() == 2
    assert plan.ledger() == [("a.site", 2, "raise"), ("a.site", 4, "raise")]
    assert plan.unfired() == []
    s = plan.summary()
    assert s["scripted"] == {"a.site:raise": 2}
    assert s["fired"] == {"a.site:raise": 2}


def test_plan_unfired_flags_unreached_calls():
    plan = FaultPlan().add("s", "raise", on=5)
    with plan:
        fault_point("s")                                # only call 1
    unfired = plan.unfired()
    assert len(unfired) == 1 and unfired[0].site == "s"


def test_plan_kinds():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("s", "explode")
    with pytest.raises(ValueError, match="1-based"):
        FaultSpec("s", on=0)
    slept = []
    plan = FaultPlan(sleep=slept.append)
    plan.add("k", "kill", on=1).add("l", "latency", on=1, delay_s=0.25)
    plan.add("t", "torn", on=1, frac=0.3)
    with plan:
        with pytest.raises(ThreadKilled) as ei:
            fault_point("k")
        assert isinstance(ei.value, InjectedFault)      # handled like any
        assert fault_point("l") is None                 # latency: no error,
        assert slept == [0.25]                          # just the delay
        spec = fault_point("t")                         # torn: RETURNED for
        assert spec is not None and spec.frac == 0.3    # the writer to enact


def test_plan_install_is_exclusive_and_scoped():
    assert fault_point("nowhere") is None   # no plan: no counting, no cost
    p1, p2 = FaultPlan(), FaultPlan()
    with p1:
        with pytest.raises(RuntimeError, match="already installed"):
            p2.install()
    with p2:                                # p1 exited: p2 may install
        fault_point("s")
    assert p2.calls("s") == 1
    assert p1.calls("nowhere") == 0         # pre-install call never counted


# ------------------------------------------------------------ atomic_write
def test_atomic_write_writes_and_cleans_tmp(tmp_path):
    dest = tmp_path / "out.bin"
    atomic_write(dest, lambda fh: fh.write(b"hello"))
    assert dest.read_bytes() == b"hello"
    assert list(tmp_path.iterdir()) == [dest]           # no tmp droppings


def test_atomic_write_crash_preserves_old_content(tmp_path):
    dest = tmp_path / "out.bin"
    dest.write_bytes(b"old-and-complete")

    def boom(fh):
        fh.write(b"new-but-")
        raise RuntimeError("writer died mid-payload")

    with pytest.raises(RuntimeError):
        atomic_write(dest, boom)
    assert dest.read_bytes() == b"old-and-complete"     # untouched
    assert list(tmp_path.iterdir()) == [dest]


def test_atomic_write_scripted_torn_write(tmp_path):
    dest = tmp_path / "seg.bin"
    dest.write_bytes(b"previous")
    payload = b"0123456789" * 10
    with FaultPlan().add("store.write", "torn", on=1, frac=0.5):
        with pytest.raises(InjectedFault) as ei:
            atomic_write(dest, lambda fh: fh.write(payload))
    assert ei.value.kind == "torn"
    torn = dest.read_bytes()
    # the tear bypassed the tmp+rename discipline ON PURPOSE: partial
    # new bytes landed straight on the destination (the damage recovery
    # tests need), not the old content and not the full payload
    assert torn == payload[:50]


# ------------------------------------------------------------ supervisor
def test_supervisor_restarts_then_recovers():
    crashes, delays = [], []
    state = {"n": 0}

    def run_once():
        state["n"] += 1
        if state["n"] <= 3:
            raise RuntimeError(f"boom {state['n']}")
        return 1

    sup = Supervisor("t", run_once, on_crash=crashes.append,
                     max_consecutive_failures=5, sleep=delays.append,
                     idle_sleep_s=0.001).start()
    deadline = time.monotonic() + 10
    while sup.crashes < 3 or sup.consecutive != 0:
        assert time.monotonic() < deadline, sup.stats()
        time.sleep(0.005)
    assert sup.stop(timeout=5)
    s = sup.stats()
    assert s["crashes"] == 3 and s["consecutive_failures"] == 0
    assert not s["degraded"] and "boom 3" in s["last_error"]
    assert len(crashes) == 3
    assert len([d for d in delays if d > 0]) >= 3       # backoff each crash


def test_supervisor_gives_up_visibly():
    gave_up = []
    sup = Supervisor("t", lambda: (_ for _ in ()).throw(RuntimeError("x")),
                     on_giveup=gave_up.append,
                     max_consecutive_failures=3, sleep=lambda s: None).start()
    deadline = time.monotonic() + 10
    while not sup.degraded:
        assert time.monotonic() < deadline, sup.stats()
        time.sleep(0.005)
    sup._thread.join(timeout=5)
    s = sup.stats()
    assert s["degraded"] and not s["alive"]             # died VISIBLY
    assert s["crashes"] == 3                            # bounded, not a spin
    assert len(gave_up) == 1


def test_supervisor_backoff_is_seeded_and_capped():
    a = Supervisor("a", lambda: 0, seed=42, backoff_base_s=0.01,
                   backoff_cap_s=0.08)
    b = Supervisor("b", lambda: 0, seed=42, backoff_base_s=0.01,
                   backoff_cap_s=0.08)
    da = [a.backoff_s(n) for n in range(1, 8)]
    db = [b.backoff_s(n) for n in range(1, 8)]
    assert da == db                         # same seed -> same jitter
    assert all(d <= 0.08 * 1.5 for d in da)             # capped (x jitter)
    assert da[0] < da[2]                                # grows at first


# ------------------------------------------------------------ async engine
class _FakeBackend:
    """Minimal AsyncEngine backend: fails the first ``fail_first`` calls,
    then answers with constant neighbors."""

    def __init__(self, fail_first=0, block_on=None):
        self.cfg = SCFG
        self.calls = 0
        self.fail_first = fail_first
        self.block_on = block_on
        self.index = None

    def query_batch(self, ids, lens):
        self.calls += 1
        if self.block_on is not None:
            self.block_on.wait()
        if self.calls <= self.fail_first:
            raise RuntimeError(f"backend down (call {self.calls})")
        n = len(lens)
        return (np.zeros((n, SCFG.k), np.int32),
                np.zeros((n, SCFG.k), np.float32), 7)

    def stats(self):
        return {}


def test_engine_internal_failure_resolves_futures_typed():
    eng = AsyncEngine(_FakeBackend(fail_first=99), start=False)
    f1 = eng.submit(np.zeros(8, np.int8))
    f2 = eng.submit(np.zeros(8, np.int8))
    with pytest.raises(RuntimeError):       # the crash still propagates
        eng._drain_once(timeout=0.01)       # (the supervisor's signal) —
    r1, r2 = f1.result(timeout=1), f2.result(timeout=1)
    assert isinstance(r1, Rejected) and r1.reason == "internal"
    assert "backend down" in r1.detail      # — but the futures were
    assert r2.reason == "internal"          # already resolved, typed
    assert eng.counters["shed_internal"] == 2


def test_engine_supervised_dispatch_restarts():
    eng = AsyncEngine(_FakeBackend(fail_first=1), max_wait_ms=0.0)
    try:
        r1 = eng.submit(np.zeros(8, np.int8)).result(timeout=30)
        assert isinstance(r1, Rejected) and r1.reason == "internal"
        r2 = eng.submit(np.zeros(8, np.int8)).result(timeout=30)
        assert isinstance(r2, Completed) and r2.epoch == 7
        d = eng.stats()["dispatch"]
        assert d["crashes"] == 1 and d["alive"] and not d["degraded"]
    finally:
        assert eng.close(timeout=10)


def test_engine_dispatch_giveup_drains_queue_and_sheds_new():
    eng = AsyncEngine(_FakeBackend(fail_first=10 ** 9), max_wait_ms=0.0)
    try:
        futs = [eng.submit(np.zeros(8, np.int8)) for _ in range(4)]
        deadline = time.monotonic() + 30
        while not eng._sup.degraded:
            # keep the loop fed: an empty queue is an idle (not failing)
            # iteration and would never exhaust the restart budget
            futs.append(eng.submit(np.zeros(8, np.int8)))
            assert time.monotonic() < deadline, eng.stats()["dispatch"]
            time.sleep(0.01)
        outs = [f.result(timeout=30) for f in futs]
        assert all(o.reason == "internal" for o in outs)    # none stranded
        late = eng.submit(np.zeros(8, np.int8)).result(timeout=1)
        assert late.reason == "internal"    # degraded: shed at the door
        assert "degraded" in late.detail
    finally:
        eng.close(timeout=10)


def test_engine_close_reports_wedged_thread():
    gate = threading.Event()
    eng = AsyncEngine(_FakeBackend(block_on=gate), max_wait_ms=0.0)
    fut = eng.submit(np.zeros(8, np.int8))
    deadline = time.monotonic() + 10
    while not eng.counters["batches"] and fut.done() is False \
            and eng.pending():
        assert time.monotonic() < deadline
        time.sleep(0.005)
    time.sleep(0.05)                        # let dispatch enter the backend
    assert eng.close(timeout=0.2) is False  # wedged: REPORTED, not hidden
    assert eng.stats()["wedged"]
    gate.set()                              # release the stuck thread


# ------------------------------------------------------------ fleet health
def test_fleet_retries_failed_batch_on_other_replica(data, index):
    fleet = ReplicaFleet(index, SCFG, n_replicas=2, start_ingest=False)
    q, ql = data["query_ids"][:4], data["query_lens"][:4]
    want = ReplicaFleet(index, SCFG, n_replicas=1,
                        start_ingest=False).query_batch(q, ql)
    with FaultPlan().add("replica.query", "raise", on=1):
        nid, nd, epoch = fleet.query_batch(q, ql)
    np.testing.assert_array_equal(nid, want[0])
    np.testing.assert_array_equal(nd, want[1])
    assert epoch == want[2]
    c = fleet.counters
    assert (c["retries"], c["retry_success"]) == (1, 1)
    assert c["replica_failures"] == 1 and c["replica_quarantines"] == 0
    assert fleet.coverage() == 1.0          # one blip quarantines nobody


def test_fleet_quarantine_halfopen_probe_readmission(data, index):
    clock = FakeClock()
    fleet = ReplicaFleet(index, SCFG, n_replicas=2, start_ingest=False,
                         fail_threshold=1, quarantine_s=10.0, clock=clock)
    q, ql = data["query_ids"][:2], data["query_lens"][:2]
    with FaultPlan().add("replica.query", "raise", on={1, 2}) as plan:
        out = fleet.query_batch(q, ql)      # both replicas fail -> degraded
        assert isinstance(out, DegradedBatch) and out.coverage == 0.0
        assert (out.ids == -1).all() and np.isinf(out.dists).all()
        assert out.epoch is None and "injected" in out.detail
        out2 = fleet.query_batch(q, ql)     # still quarantined: no attempt
        assert isinstance(out2, DegradedBatch)
        assert plan.calls("replica.query") == 2     # no replica was touched
        clock.advance(10.5)                 # quarantine expires
        nid, nd, _ = fleet.query_batch(q, ql)       # half-open probe #1
        assert (nid != -2).all()
        fleet.query_batch(q, ql)                    # half-open probe #2
    c = fleet.counters
    assert c["replica_quarantines"] == 2 and c["degraded_batches"] == 2
    assert c["replica_probes"] == 2 and c["replica_readmissions"] == 2
    assert fleet.coverage() == 1.0          # fully readmitted
    health = [r["health"] for r in fleet.stats()["replicas"]]
    assert all(not h["quarantined"] and h["fails"] == 0 for h in health)


def test_fleet_degraded_flows_through_engine_typed(data, index):
    fleet = ReplicaFleet(index, SCFG, n_replicas=2, start_ingest=False,
                         fail_threshold=1, quarantine_s=60.0,
                         clock=FakeClock())
    eng = AsyncEngine(fleet, start=False)
    with FaultPlan().add("replica.query", "raise", on={1, 2}):
        fut = eng.submit(np.asarray(data["query_ids"][0]
                                    [:data["query_lens"][0]], np.int8))
        eng._drain_once(timeout=0.01)
    out = fut.result(timeout=5)
    assert isinstance(out, Degraded) and not out.ok and out.degraded
    assert out.coverage == 0.0 and out.epoch is None
    assert eng.counters["degraded"] == 1


def test_fleet_ingest_crash_resolves_ticket_and_restarts(data):
    # fresh index: this test MUTATES it (the module fixture stays pure)
    index = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    index._ensure_built()
    epoch0 = index.epoch
    fleet = ReplicaFleet(index, SCFG, n_replicas=2)
    try:
        with FaultPlan().add("ingest.apply", "kill", on=1):
            t1 = fleet.ingest(data["ref_ids"][:4], data["ref_lens"][:4])
            assert t1.wait(timeout=30)      # resolved, not stranded
            assert not t1.ok and "injected" in t1.error
            t2 = fleet.ingest(data["ref_ids"][:4], data["ref_lens"][:4])
            assert t2.wait(timeout=30) and t2.ok and t2.error is None
        st = fleet.stats()
        assert st["counters"]["ingest_failures"] == 1
        assert st["counters"]["ingests"] == 1
        assert st["ingest"]["crashes"] == 1 and st["ingest"]["alive"]
        assert not st["ingest"]["degraded"]
        assert index.epoch == epoch0 + 1    # the retry actually landed
    finally:
        assert fleet.close(timeout=10)


def test_fleet_close_resolves_queued_tickets(data):
    index = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    index._ensure_built()
    fleet = ReplicaFleet(index, SCFG, n_replicas=1, start_ingest=False)
    t = fleet.ingest(data["ref_ids"][:4], data["ref_lens"][:4])
    assert fleet.close(timeout=5)           # no loop ever ran: still queued
    assert t.is_set() and not t.ok and "Shutdown" in t.error
