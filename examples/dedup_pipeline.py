"""The paper's LSH as LM-data infrastructure: sketch a token corpus, join
near-duplicates at several Hamming radii, show the precision/recall of each
radius against planted twins (Manku-style web dedup, the lineage ScalLoPS
builds on).

    PYTHONPATH=src python examples/dedup_pipeline.py
"""
import numpy as np

from repro.core.hamming import all_pairs_hamming
from repro.data.lm_data import (LMDataConfig, dedup_corpus, synth_corpus,
                                token_signatures)

cfg = LMDataConfig(vocab_size=32000, seq_len=512, global_batch=8, seed=42)
docs, lens = synth_corpus(cfg, n_docs=200, dup_fraction=0.2)
n_twins = 40
print(f"corpus: {len(docs)} docs x {cfg.seq_len} tokens, "
      f"{n_twins} planted near-duplicate twins (2% token mutation)")

sigs = token_signatures(docs, lens, k=cfg.dedup_k, f=cfg.dedup_f)
dist = np.asarray(all_pairs_hamming(sigs, sigs))
twin_d = [dist[200 - n_twins + i].min(initial=999, where=np.arange(200) !=
          200 - n_twins + i) for i in range(n_twins)]
offdiag = dist[np.triu_indices(160, k=1)]
print(f"signature distance: twins median={np.median(twin_d):.0f} bits, "
      f"unrelated median={np.median(offdiag):.0f} bits (f={cfg.dedup_f})")

for d in (8, 16, 28, 40):
    keep, n_dropped = dedup_corpus(docs, lens, k=cfg.dedup_k,
                                   f=cfg.dedup_f, d=d)
    tp = (~keep[-n_twins:]).sum()
    fp = (~keep[:-n_twins]).sum()
    print(f"d={d:3d}: dropped {n_dropped:3d} "
          f"(twins caught {tp}/{n_twins}, clean docs lost {fp})")
