"""Indexed protein search: build the reference index once, serve queries many
times (the paper §5.3 amortization, made a first-class artifact).

    PYTHONPATH=src python examples/indexed_search.py
"""
import os
import tempfile

import numpy as np

from repro.core import LSHConfig, encode_batch
from repro.index import QueryEngine, ServingConfig, SignatureIndex

refs = [
    "MKTAYIAKQRQISFVKSHFSRQLEERLGLIEVQAPILSRVGDGTQDNLSGAEKAVQVKVKALPDAQ",
    "MDESFGLLLESMQARIEELNDVLRLINKLLRSTDAAQSPSLAQRWQQLSAEYQQLSHLLEPLL",
    "MSKGEELFTGVVPILVELDGDVNGHKFSVSGEGEGDATYGKLTLKFICTTGKLPVPWPTLVTTL",
    "MALWMRLLPLLALLALWGPDPAAAFVNQHLCGSHLVEALYLVCGERGFFYTPKTRREAEDLQV",
]
ref_ids, ref_lens = encode_batch(refs)

# --- build once, persist, reload (fingerprint-verified) -------------------
cfg = LSHConfig(k=3, T=13, f=32, d=2)
index = SignatureIndex.build(cfg, ref_ids, ref_lens)
path = os.path.join(tempfile.gettempdir(), "indexed_search_demo.npz")
index.save(path)
index = SignatureIndex.load(path, expected_cfg=cfg)
print(f"index: {index.size} refs, layout={index.layout}, "
      f"bands={index.n_bands}, fingerprint={index.fingerprint}")

# --- incremental growth: add a reference after the initial build ----------
extra = ["MTEYKLVVVGAGGVGKSALTIQLIQNHFVDEYDPTIEDSYRKQVVIDGETCLLDILDTAGQ"]
e_ids, e_lens = encode_batch(extra, max_len=ref_ids.shape[1])
index.add(e_ids, e_lens)    # seals an append-only segment (lazily, on the
print(f"after add(): {index.size} refs "         # next probe/refresh/save)
      f"(epoch {index.epoch}: resident buckets untouched)")

# --- serve: micro-batched top-k with optional SW re-rank ------------------
all_ids = np.concatenate([ref_ids, e_ids])
all_lens = np.concatenate([ref_lens, e_lens])
engine = QueryEngine(index, ServingConfig(k=3, rerank=True),
                     ref_seqs=(all_ids, all_lens))
engine.submit("MDESFGLLLESMQARIEELNDVLRLINKWLRSTDAAQSPSLAQRWQQLSAEYQQLSHL")
engine.submit("MTEYKLVVVGAGGVGKSALTIQLIQNHFVDEYDPTIEDSYRKQVVIDGETCL")
engine.submit("ACDEFGHIKLMNPQRSTVWYACDEFGHIKLMNPQRSTVWYACDEFGHIKLMNPQRSTVW")
for qi, (nid, nd) in enumerate(engine.flush()):
    found = [(int(r), int(dd)) for r, dd in zip(nid, nd) if r >= 0]
    print(f"query {qi}: top-k (ref, hamming) = {found or 'no neighbors'}")

s = engine.stats()
print(f"served {s['n_queries']} queries in {s['n_batches']} batch(es), "
      f"p50={s['p50_ms']:.1f}ms")
os.unlink(path)
