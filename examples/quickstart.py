"""Quickstart: ScalLoPS protein similarity search in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import LSHConfig, ScalLoPS, encode_batch
from repro.core.join import pairs_to_set
from repro.align.smith_waterman import percent_identity
from repro.core.alphabet import encode

# A tiny reference "database" and two queries: one true homolog (a mutated
# copy of ref 1), one unrelated.
refs = [
    "MKTAYIAKQRQISFVKSHFSRQLEERLGLIEVQAPILSRVGDGTQDNLSGAEKAVQVKVKALPDAQ",
    "MDESFGLLLESMQARIEELNDVLRLINKLLRSTDAAQSPSLAQRWQQLSAEYQQLSHLLEPLL",
    "MSKGEELFTGVVPILVELDGDVNGHKFSVSGEGEGDATYGKLTLKFICTTGKLPVPWPTLVTTL",
]
queries = [
    "MDESFGLLLESMQARIEELNDVLRLINKWLRSTDAAQSPSLAQRWQQLSAEYQQLSHL",  # ~ref 1
    "ACDEFGHIKLMNPQRSTVWYACDEFGHIKLMNPQRSTVWYACDEFGHIKLMNPQRSTVW",  # junk
]

ref_ids, ref_lens = encode_batch(refs)
qry_ids, qry_lens = encode_batch(queries)

# Paper's best-quality operating point: k=4 T=22 d=0 (§5.2). Small demo set,
# so use k=3/T=13/d=2 which tolerates short sequences better.
sl = ScalLoPS(LSHConfig(k=3, T=13, f=32, d=2, max_pairs=64))
ref_sigs = sl.signatures(ref_ids, ref_lens)     # MapReduce job 1 (refs)
qry_sigs = sl.signatures(qry_ids, qry_lens)     # MapReduce job 1 (queries)
pairs, count, overflowed = sl.search(qry_sigs, ref_sigs)  # MapReduce job 2
assert not bool(overflowed), "grow max_pairs and re-run"

print(f"signatures (refs):    {np.asarray(ref_sigs).ravel()}")
print(f"signatures (queries): {np.asarray(qry_sigs).ravel()}")
print(f"candidate pairs (query, ref): {sorted(pairs_to_set(pairs))}")

for q, r in sorted(pairs_to_set(pairs)):
    pid, length, score = percent_identity(encode(queries[q]),
                                          encode(refs[r]))
    print(f"  query {q} vs ref {r}: PID={pid:.0f}% over {length} cols "
          f"(SW score {score})")
