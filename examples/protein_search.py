"""End-to-end ScalLoPS workflow (the paper's §4 pipeline at benchmark scale):
synthetic metagenomic query set vs reference DB, distributed MapReduce join,
quality report against planted ground truth.

    PYTHONPATH=src python examples/protein_search.py [--shards 4]
"""
import argparse
import os
import sys
import time

# multi-shard demo: re-exec with host platform devices BEFORE jax import
ap = argparse.ArgumentParser()
ap.add_argument("--shards", type=int, default=4)
ap.add_argument("--_worker", action="store_true")
args = ap.parse_args()
if not args._worker and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={args.shards}"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import LSHConfig, ScalLoPS  # noqa: E402
from repro.core.mapreduce import MapReduceConfig, distributed_flip_join, ring_sweep  # noqa: E402
from repro.core.join import pairs_to_set  # noqa: E402
from repro.data import SyntheticProteinConfig, make_protein_sets  # noqa: E402
from repro.align.smith_waterman import batch_percent_identity  # noqa: E402

data = make_protein_sets(SyntheticProteinConfig(
    n_refs=256, n_homolog_queries=64, n_decoy_queries=192,
    ref_len_mean=150, ref_len_std=30, sub_rates=(0.05, 0.15), seed=7))
truth = {(q, p) for q, (p, _) in enumerate(data["truth"]) if p >= 0}

cfg = LSHConfig(k=3, T=13, f=32, d=1, max_pairs=1 << 14)
sl = ScalLoPS(cfg)
t0 = time.time()
ref_sigs = sl.signatures(data["ref_ids"], data["ref_lens"])
qry_sigs = sl.signatures(data["query_ids"], data["query_lens"])
print(f"[siggen] {len(ref_sigs)+len(qry_sigs)} signatures "
      f"in {time.time()-t0:.2f}s")

n = jax.device_count()
mesh = jax.make_mesh((n,), ("data",))
mrc = MapReduceConfig(n_shards=n, shuffle_capacity=8192,
                      max_pairs_per_shard=1 << 14)
t0 = time.time()
pairs, counts, dropped = distributed_flip_join(
    qry_sigs, ref_sigs,
    jnp.arange(qry_sigs.shape[0], dtype=jnp.int32),
    jnp.arange(ref_sigs.shape[0], dtype=jnp.int32),
    f=cfg.f, d=cfg.d, mesh=mesh, cfg=mrc)
got = pairs_to_set(np.asarray(pairs).reshape(-1, 2))
print(f"[join/shuffle] {len(got)} pairs on {n} shards in "
      f"{time.time()-t0:.2f}s (dropped={int(np.asarray(dropped).sum())})")

t0 = time.time()
rp, _ = ring_sweep(qry_sigs, ref_sigs, d=cfg.d, mesh=mesh,
                   max_pairs_per_shard=1 << 14)
got_ring = pairs_to_set(np.asarray(rp).reshape(-1, 2))
print(f"[ring sweep]   {len(got_ring)} pairs in {time.time()-t0:.2f}s "
      f"(streams refs around the ring, overlap comm/compute)")
assert got_ring == got

# Single-device join with overflow detection: start with a deliberately
# small pair buffer; SearchResult.overflowed drives grow-and-retry, so no
# pair is ever silently truncated.
mp = 64
while True:
    res = sl.search(qry_sigs, ref_sigs, max_pairs=mp)
    if not bool(res.overflowed):
        break
    print(f"[warn] pair buffer overflow at max_pairs={mp} "
          f"(true count {int(res.count)}) — growing capacity and retrying")
    mp *= 2
assert pairs_to_set(res.pairs) == got, "local join must match distributed"
print(f"[join/local]   {int(res.count)} pairs at max_pairs={mp} "
      f"(overflow-checked)")

recall = len(got & truth) / len(truth)
print(f"[quality] recall of planted homologs: {recall:.2%} "
      f"({len(got & truth)}/{len(truth)})")
sub = sorted(got)[:50]
pids = batch_percent_identity([(q, r, 0) for q, r in sub],
                              data["query_ids"], data["query_lens"],
                              data["ref_ids"], data["ref_lens"])
pids = pids[np.isfinite(pids)]
if len(pids):
    print(f"[quality] PID of emitted pairs: median={np.median(pids):.0f}% "
          f"q1={np.percentile(pids, 25):.0f}% q3={np.percentile(pids, 75):.0f}%")
