"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU
with the full production stack — LSH dedup stage, AdamW + warmup-cosine,
grad accumulation, periodic atomic checkpoints, and crash-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(~100M params: 12L x d=512 x ff=2048, vocab 8192 — a scaled member of the
yi-9b family; the full configs are exercised by the dry-run.)
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.lm_data import LMDataConfig, dedup_corpus, lm_batches, synth_corpus
from repro.models import ModelConfig
from repro.train import (AdamWConfig, TrainConfig, init_train_state,
                         make_train_step)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = ModelConfig(
    name="yi-100m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2048, vocab_size=8192, attn_chunk=128, ce_chunk=128)
dc = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=256, global_batch=8,
                  seed=0)

# --- the paper's technique in the data plane: LSH near-dup filtering
docs, lens = synth_corpus(dc, n_docs=128, dup_fraction=0.15)
keep, n_dups = dedup_corpus(docs, lens)
print(f"[dedup] ScalLoPS SimHash stage dropped {n_dups}/{len(keep)} "
      f"near-duplicate docs from the probe corpus")

tc = TrainConfig(n_microbatches=2,
                 opt=AdamWConfig(lr=3e-4, warmup_steps=30,
                                 total_steps=args.steps))
step_fn = jax.jit(make_train_step(cfg, tc, mesh=None))
state = init_train_state(jax.random.PRNGKey(0), cfg)
n_params = sum(x.size for x in jax.tree.leaves(state.params))
print(f"[init] {n_params/1e6:.1f}M params")

mgr = CheckpointManager(args.ckpt_dir, keep_last=2, async_writes=True)
start = 0
if mgr.latest_step() is not None:
    state, start = mgr.restore(state)
    print(f"[resume] from step {start}")

t0 = time.time()
for s in range(start, args.steps):
    x, y = lm_batches(dc, s)
    state, m = step_fn(state, {"inputs": x, "targets": y})
    if s % 20 == 0 or s == args.steps - 1:
        tok_s = (s - start + 1) * dc.global_batch * dc.seq_len \
            / max(time.time() - t0, 1e-9)
        print(f"step {s:4d} loss={float(m['loss']):.4f} "
              f"lr={float(m['lr']):.2e} tok/s={tok_s:.0f}")
    if (s + 1) % 100 == 0:
        mgr.save(s + 1, state, block=False)   # async writer
mgr.wait()
mgr.save(args.steps, state)
print(f"[done] final loss {float(m['loss']):.4f}; "
      f"checkpoints in {args.ckpt_dir}")
