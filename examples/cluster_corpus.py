"""Many-against-many clustering: one corpus in, protein families out.

The all-pairs analogue of indexed_search.py — instead of queries vs. a
reference DB, the whole corpus is joined against itself (LSH self-join),
candidate pairs are scored with batched Smith-Waterman waves, and the
thresholded similarity graph is clustered into families.

    PYTHONPATH=src python examples/cluster_corpus.py
"""
import numpy as np

from repro.allpairs import AllPairsConfig, WaveConfig, all_pairs_search
from repro.core import LSHConfig
from repro.data import FamilyCorpusConfig, make_family_corpus

# --- a corpus with planted families (3 mutated copies per founder) --------
corpus = make_family_corpus(FamilyCorpusConfig(
    n_families=16, family_size=3, n_singletons=48,
    len_mean=120, len_std=20, sub_rate=0.04, seed=11))
ids, lens, truth = corpus["ids"], corpus["lens"], corpus["labels"]
print(f"corpus: {len(lens)} sequences "
      f"({16 * 3} family members + 48 singletons, shuffled)")

# --- corpus -> self-join -> SW waves -> families --------------------------
cfg = AllPairsConfig(
    lsh=LSHConfig(k=3, T=13, f=32, d=2),    # d=2: tolerate ~96% identity
    min_pid=60.0,                           # family edge: >= 60% identity
    wave=WaveConfig(wave_batch=32, with_pid=True))
res = all_pairs_search(ids, lens, cfg)

print(f"self-join: {res.join.n_candidates} candidate pairs "
      f"(of {len(lens) * (len(lens) - 1) // 2} possible)")
print(f"scoring:   {res.scored.n_waves} SW waves, "
      f"{res.scored.n_shapes} fixed shapes")
print(f"families:  {res.families.n_families} discovered "
      f"(edges kept: {int(res.families.edge_mask.sum())})")

# --- gap-mode robustness: score-only waves under linear AND affine --------
# (Gotoh, BLOSUM62 companions -11/-1) produce identical families at the
# calibrated score threshold — family alignments in this corpus are
# gapless, where the two gap models score identically.
def _score_cfg(gap_mode):
    return AllPairsConfig(
        lsh=cfg.lsh, min_score=150,
        wave=WaveConfig(wave_batch=32, with_pid=False, gap_mode=gap_mode))

lin = all_pairs_search(ids, lens, _score_cfg("linear"), index=res.index)
aff = all_pairs_search(ids, lens, _score_cfg("affine"), index=res.index)
assert np.array_equal(lin.labels, aff.labels), \
    "gap modes disagree on family labels at the calibrated threshold"
print(f"gap modes: linear == affine labels at SW score >= 150 "
      f"({lin.families.n_families} families either way)")

# --- print them, checked against the planted ground truth -----------------
for n, fam in enumerate(res.families.families):
    t = set(int(x) for x in truth[fam])
    tag = f"= planted family {t.pop()}" if len(t) == 1 else f"MIXED {sorted(t)}"
    pids = [f"{p:.0f}%" for p in res.scored.pid[
        np.isin(res.pairs[:, 0], fam) & np.isin(res.pairs[:, 1], fam)
        & res.families.edge_mask]]
    print(f"  family {n}: members={list(map(int, fam))} "
          f"edge PIDs={pids} {tag}")
